"""Cost planner: Appendix A/B analysis for your own cluster.

Reproduces the paper's headline numbers (Fig 1) and then answers: at YOUR
scale/failure rate/overhead, how much does Checkmate save, and what does the
shadow plane cost (§4.4 resource plan)?

    PYTHONPATH=src python examples/cost_planner.py
"""
from repro.core import costmodel as cm
from repro.net.planner import PlanInput, plan


def main():
    p = cm.CostParams()                        # LLaMA3-405B defaults
    print("== Paper validation (LLaMA3-405B, 16K H100, Meta failure rate) ==")
    print(f"iteration time (App. A): {cm.iteration_time(cm.LLAMA3_405B, 400e12, 16384):.2f} s"
          f"  (paper: 4.58 s)")
    print(f"optimal checkpoint freq f*: every {cm.optimal_frequency(p):.0f} iterations")
    print(f"wasted GPU-h at f* (SOTA):  {cm.wasted_gpu_hours_sota_min(p):,.0f}")
    print(f"wasted GPU-h (Checkmate):   {cm.wasted_gpu_hours_checkmate(p):,.0f}")
    print(f"30-min interval waste:      {cm.wasted_gpu_hours_sota(393, p):,.0f}"
          f"  (paper: ~1.7M)")
    print(f"CPU-node-hours for shadow:  {cm.cpu_node_hours(p):,.0f} (paper: 166K)")
    print(f"net savings: ${cm.savings_usd(p):,.0f}")

    print("\n== Fig 11 sweep: saved GPU-h/day by scale (Meta failure rate) ==")
    sweep = cm.sweep_overhead(p, [0.01, 0.1, 0.5, 1.2, 5.0],
                              [4096, 8192, 16384])
    hdr = "omega(s): " + "".join(f"{w:>10}" for w, _ in sweep[4096])
    print(hdr)
    for n, rows in sweep.items():
        print(f"N={n:<6d}  " + "".join(f"{s:>10.0f}" for _, s in rows))

    print("\n== §4.4 network resource plan (16K accelerators, 128 DP groups) ==")
    pl = plan(PlanInput(n_accelerators=16384, dp_groups=128,
                        ranks_per_group=128),
              grad_bytes_total=405e9 * 2, iter_time_s=4.58)
    print(f"multicast streams: {pl.multicast_streams}  extra ports: {pl.extra_ports}"
          f"  ({pl.extra_port_fraction:.2%} of fabric)")
    print(f"hosts: {pl.hosts}  grad bytes/host: {pl.grad_bytes_per_host/1e6:.0f} MB"
          f"  PCIe util: {pl.pcie_util:.1%}  feasible: {pl.feasible}")


if __name__ == "__main__":
    main()
