"""Batched serving example: prefill + KV-cache greedy decode across
families (dense GQA, SSM constant-state, hybrid).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.configs as C
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.models import registry
from repro.train.step import build_decode_step


def run(arch: str, batch=4, prompt=32, gen=12):
    cfg = C.get(arch).reduced()
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    rng = np.random.default_rng(0)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, rules)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt)),
                         jnp.int32)
    cache, logits = registry.prefill(params, cfg, rules, tokens,
                                     max_seq=prompt + gen)
    decode = jax.jit(build_decode_step(cfg, rules), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, cache = decode(params, cache, tok)
        out.append(int(tok[0, 0]))
    dt = time.time() - t0
    print(f"{arch:22s} [{cfg.family:6s}] decoded {out[:6]}... "
          f"{batch * (gen - 1) / dt:7.1f} tok/s")


def main():
    for arch in ("tinyllama-1.1b", "mamba2-2.7b", "zamba2-1.2b", "glm4-9b"):
        run(arch)


if __name__ == "__main__":
    main()
