"""Fabric failure drills through the chaos harness (docs/harness.md),
ending in a recovery that is bit-identical to an uninterrupted run
(paper §4 + Fig 9).

    PYTHONPATH=src python examples/fabric_failures.py

All failure injection rides the harness API — declarative Scenarios whose
`FabricFailure`s reach the event-driven simulator through the
PacketizedChannel, with the invariant registry checking every step:

  1. spine kill     -> ECMP reroutes; ring and capture both complete.
  2. uplink cut     -> same, at smaller blast radius.
  3. shadow NIC cut -> training unaffected, but that iteration's capture
     is incomplete; the channel surfaces it as a gated delivery and the
     shadow cluster skips the apply (contiguity preserved).
  4. gated capture + training failure (full stack): recovery consolidates
     one step earlier and the resumed run converges bit-identically.
"""
import numpy as np

from repro.harness import (ChannelSpec, FabricFailure, FailureSchedule,
                           Scenario, run_scenario)

RAIL = ChannelSpec(kind="packetized", topology="rail-optimized",
                   n_dp_groups=2, ranks_per_group=4)


def fabric_of(result, step):
    """The FabricResult of ``step``'s delivery (channel-level runs poll
    one delivery per step)."""
    for rec in result.trace.records:
        for p in rec.polls:
            if p.step == step:
                return p.fabric
    raise KeyError(step)


def main():
    drills = {
        "spine kill": FabricFailure(step=2, kind="switch", target="spine0"),
        "uplink cut": FabricFailure(step=2, kind="link",
                                    target=("leaf0", "spine0")),
        "shadow cut": FabricFailure(step=2, kind="capture"),
    }
    for label, failure in drills.items():
        sc = Scenario(name=f"drill-{label.replace(' ', '-')}", seed=5,
                      steps=3, channel=RAIL,
                      schedule=FailureSchedule(fabric=(failure,)))
        result = run_scenario(sc)
        f = fabric_of(result, 2)
        print(f"{label:<12}: ok={result.passed} ring_ok={f.ring_completed} "
              f"capture_ok={f.reassembled_ok} rerouted={f.rerouted} "
              f"retx={f.retransmits} missing={f.missing_captures}")
        assert result.passed, result.violations
        assert f.ring_completed              # training traffic never stalls

    # couple the capture loss to training: the channel's fabric loses
    # iteration LOST mid-run (every shadow NIC cut), so its delivery is
    # gated and the shadow apply skipped; a training failure at LOST+1
    # then recovers from LOST-1, bit-identically to the reference run the
    # harness executes alongside
    LOST = 4
    sc = Scenario(
        name="fabric-gated-recovery-example", level="full",
        arch="tinyllama-1.1b", steps=8, batch=2, seq=16, seed=5,
        channel=RAIL,
        schedule=FailureSchedule(
            train_fail_steps=(LOST + 1,),
            fabric=(FabricFailure(step=LOST, kind="capture"),)))
    result = run_scenario(sc)
    trace = result.trace
    same = all(np.array_equal(trace.final["params"][k],
                              trace.ref_final["params"][k])
               for k in trace.ref_final["params"])
    print(f"recovery    : recovered_at={trace.stats.recovered_at} "
          f"gated={trace.checkpointer.skipped_steps} bit_identical={same}")
    assert result.passed, result.violations
    assert same and trace.stats.recovered_at == [LOST - 1]
    assert trace.checkpointer.skipped_steps == [LOST]


if __name__ == "__main__":
    main()
