"""Fabric failure drills on the event-driven network simulator, ending in a
recovery that is bit-identical to an uninterrupted run (paper §4 + Fig 9).

    PYTHONPATH=src python examples/fabric_failures.py

Three scenarios on a rail-optimized leaf/spine fabric shared by two DP
groups:
  1. spine kill     -> ECMP reroutes; ring and capture both complete.
  2. uplink cut     -> same, at smaller blast radius.
  3. shadow NIC cut -> training unaffected, but that iteration's capture is
     incomplete; the PacketizedChannel surfaces it as a gated delivery, the
     shadow cluster skips the apply, and when the training node later
     fails, `core.recovery` consolidates one step earlier and the resumed
     run converges bit-identically.
"""
import numpy as np
import jax

import repro.configs as C
from repro.core.buckets import layout_for_tree
from repro.core.channel import PacketizedChannel
from repro.core.checkpoint import CheckmateCheckpointer
from repro.core.recovery import FailurePlan
from repro.core.shadow import ShadowCluster
from repro.dist.sharding import ShardingRules, make_smoke_mesh
from repro.net.simulator import FailureSpec, simulate_fabric
from repro.optim import OptimizerConfig
from repro.train.loop import train
from repro.train.step import make_train_state

FABRIC = dict(n_dp_groups=2, ranks_per_group=64,
              grad_bytes_per_group=64 * 8192, topology="rail",
              n_shadow_nodes=2, ranks_per_leaf=16)


def main():
    mid = simulate_fabric(**FABRIC).duration_s / 2

    r = simulate_fabric(**FABRIC,
                        failures=[FailureSpec(mid, "switch", "spine0")])
    print(f"spine kill   : rerouted={r.rerouted} retx={r.retransmits} "
          f"capture_ok={r.reassembled_ok}")

    r = simulate_fabric(**FABRIC,
                        failures=[FailureSpec(mid, "link",
                                              ("leaf0", "spine1"))])
    print(f"uplink cut   : rerouted={r.rerouted} retx={r.retransmits} "
          f"capture_ok={r.reassembled_ok}")

    fab = simulate_fabric(**FABRIC,
                          failures=[FailureSpec(mid, "shadow_nic", "s0"),
                                    FailureSpec(mid, "shadow_nic", "s1")])
    print(f"shadow cut   : ring_ok={fab.ring_completed} "
          f"capture_ok={fab.reassembled_ok} "
          f"missing={fab.missing_captures}")

    # couple the capture loss to training: the channel's own fabric loses
    # iteration LOST mid-run (both shadow NICs cut), so its delivery is
    # gated and the shadow apply skipped; a training failure at LOST+1
    # then recovers from LOST-1
    LOST, steps, batch, seq, seed = 4, 8, 2, 16, 5
    cfg = C.get("tinyllama-1.1b").reduced()
    rules = ShardingRules(make_smoke_mesh())
    opt = OptimizerConfig(lr=1e-3)
    state_a, _ = train(cfg, rules, steps=steps, batch=batch, seq=seq,
                       opt=opt, seed=seed)

    s0 = make_train_state(jax.random.PRNGKey(seed), cfg, rules)
    shadow = ShadowCluster(layout_for_tree(s0.params), opt, n_nodes=2)
    shadow.bootstrap(s0.params, s0.mu, s0.nu, 0)
    channel = PacketizedChannel(topology="rail-optimized",
                                n_dp_groups=2, ranks_per_group=4,
                                failures_at={LOST: "capture"})
    ck = CheckmateCheckpointer(shadow, channel=channel)
    state_b, stats = train(
        cfg, rules, steps=steps, batch=batch, seq=seq, opt=opt, seed=seed,
        state=s0, checkpointer=ck,
        failure_plan=FailurePlan((LOST + 1,)))

    same = all(np.array_equal(np.asarray(state_a.params[k]),
                              np.asarray(state_b.params[k]))
               for k in state_a.params)
    print(f"recovery     : recovered_at={stats.recovered_at} "
          f"gated={ck.skipped_steps} bit_identical={same}")
    assert same and stats.recovered_at == [LOST - 1]
    assert ck.skipped_steps == [LOST]


if __name__ == "__main__":
    main()
