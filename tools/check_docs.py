"""Docs CI check: execute fenced ``python`` code blocks in docs/*.md and
README.md, and verify that relative markdown links resolve.

    PYTHONPATH=src python tools/check_docs.py [files...]

Conventions:
  * ```python blocks are executed top-to-bottom, each file in ONE shared
    namespace (so a later block may use names a previous block defined);
    a failing block reports its file and line.
  * any other fence language (bash, text, ...) is skipped.
  * links `[x](target)` with a non-http(s), non-anchor target must resolve
    to an existing file/dir relative to the markdown file.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

FENCE = re.compile(r"^```(\w*)\s*$")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def code_blocks(text: str):
    """Yield (lang, start_line, source) for each fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m:
            lang, start = m.group(1), i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield lang, start + 1, "\n".join(body)
        i += 1


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    rel = path.relative_to(ROOT)

    in_fence = False
    for n, line in enumerate(text.splitlines(), 1):
        if FENCE.match(line) or line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            target = target.split("#", 1)[0]
            if target and not (path.parent / target).exists():
                errors.append(f"{rel}:{n}: broken link -> {target}")

    ns: dict = {"__name__": f"doccheck_{path.stem}"}
    for lang, line, src in code_blocks(text):
        if lang != "python":
            continue
        try:
            exec(compile(src, f"{rel}:{line}", "exec"), ns)  # noqa: S102
        except Exception as e:                    # report, keep checking
            errors.append(f"{rel}:{line}: code block failed: "
                          f"{type(e).__name__}: {e}")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    files = ([Path(a).resolve() for a in args] if args else
             sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"])
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(files)
    if errors:
        print(f"check_docs: {len(errors)} error(s) across {n_files} files",
              file=sys.stderr)
        return 1
    print(f"check_docs: {n_files} files ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
